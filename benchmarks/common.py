"""Shared benchmark infrastructure: the training-log corpus (real timed
grid searches over synthetic datasets, cached to disk) and the makespan
metrics from the paper (§V)."""
from __future__ import annotations

import math
import time
from pathlib import Path


from repro.core.estimator import BlockSizeEstimator
from repro.core.gridsearch import grid_search, grid_stats
from repro.core.log import ExecutionLog
from repro.data.datasets import gaussian_blobs
from repro.data.executor import Environment

ART = Path(__file__).resolve().parent.parent / "artifacts"
CACHE = ART / "bench_cache"

# the paper's single-node testbed: 64 cores, 256 GB (per-task budget =
# node RAM / cores); dispatch overhead ~200us per task (PyCOMPSs-scale)
ENV64 = Environment(name="node64", n_workers=64, n_nodes=1,
                    mem_limit_mb=4096.0, dispatch_overhead_s=2e-4,
                    ram_gb=256)
# the MN4-style multi-node environment: 16 nodes x 48 cores
ENV_MN = Environment(name="mn16", n_workers=256, n_nodes=16,
                     mem_limit_mb=2048.0, dispatch_overhead_s=4e-4,
                     ram_gb=96 * 16)

# training corpus: varied shapes x algorithms (test sets are held out)
TRAIN_SPECS = [
    (2048, 32, "kmeans"), (2048, 32, "rf"),
    (8192, 16, "kmeans"), (8192, 16, "rf"),
    (4096, 96, "kmeans"), (4096, 96, "rf"),
    (1024, 256, "kmeans"), (1024, 256, "rf"),
    (512, 1024, "kmeans"), (512, 1024, "rf"),
    (16384, 8, "kmeans"), (2048, 128, "gmm"),
    (4096, 32, "gmm"), (2048, 64, "csvm"), (4096, 24, "csvm"),
    (1024, 128, "pca"), (2048, 48, "pca"), (512, 256, "pca"),
]


def makespan_metrics(t_star: float, stats: dict) -> dict:
    """makespan ratio t_other/t*; reduction (t_other - t*)/t_other."""
    out = {}
    for key in ("best", "avg", "worst"):
        t_other = stats[key]
        out[f"ratio_{key}"] = t_other / t_star
        out[f"red_{key}"] = (t_other - t_star) / t_other
    return out


def build_training_log(env: Environment = ENV64, *, mult: int = 1,
                       tag: str = "node64", verbose: bool = False,
                       specs=None) -> ExecutionLog:
    """Real timed grid searches over the training corpus (cached)."""
    CACHE.mkdir(parents=True, exist_ok=True)
    path = CACHE / f"log_{tag}.jsonl"
    if path.exists():
        return ExecutionLog.load(path)
    log = ExecutionLog()
    for i, (n, m, algo) in enumerate(specs or TRAIN_SPECS):
        X, y = gaussian_blobs(n, m, seed=100 + i)
        t0 = time.time()
        log, _ = grid_search(X, y, algo, env, mult=mult, log=log)
        if verbose:
            print(f"  [log] {algo} {n}x{m}: {time.time()-t0:.1f}s wall",
                  flush=True)
    log.save(path)
    return log


def eval_on(est: BlockSizeEstimator, X, y, algo, env, *, mult=1,
            row_only=False):
    """Grid-search a held-out dataset, compare predicted cell vs the grid."""
    _, grid = grid_search(X, y, algo, env, mult=mult, row_only=row_only)
    stats = grid_stats(grid)
    pr, pc = est.predict_partitions(X.shape[0], X.shape[1], algo,
                                    env.features())
    if row_only:
        pc = 1
    t_star = grid.get((pr, pc), float("inf"))
    if math.isinf(t_star):
        # predicted cell outside/failed: fall back to nearest finite (rare)
        t_star = stats["worst"]
    return {"p_r": pr, "p_c": pc, "t_star": t_star, **stats,
            **makespan_metrics(t_star, stats)}


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
