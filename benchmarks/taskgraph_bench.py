"""Task-graph runtime benchmarks: DAG vs per-phase barrier scheduling, and
grid search with vs without cross-cell measurement reuse.

Writes ``BENCH_taskgraph.json`` at the repo root:

  * ``schedule`` -- for fine-partitioned kmeans/pca/gmm workloads, the
    modeled makespan under the DAG list schedule vs the per-phase barrier
    schedule the eager executor produced, computed from the SAME measured
    task durations (one run, two schedules -- no timing-noise asymmetry);
  * ``gridsearch_reuse`` -- wall time of a full kmeans sweep exhaustive vs
    with ``reuse_measurements=True`` (each unique task body/signature
    executed once, elsewhere replayed through the scheduler), with the
    argmin label checked identical.

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.algorithms import run as run_algo
from repro.core.gridsearch import grid_search, grid_stats
from repro.data.datasets import gaussian_blobs
from repro.data.distarray import DistArray
from repro.data.executor import Environment, TaskExecutor

from benchmarks.common import csv_row

OUT = Path(__file__).resolve().parent.parent / "BENCH_taskgraph.json"

# fine partitionings on an 8-worker node: many small tasks, deep reduce
# trees -- the regime where per-phase barriers over-serialize the graph.
# Dispatch overhead is identical under both schedules (a serial master-side
# sum), so the comparison environment uses a fast 10us dispatch to keep the
# schedule difference visible rather than drowned in a common constant.
SCHED_CASES = [
    ("kmeans", 16384, 64, 64, 4),
    ("kmeans", 16384, 64, 128, 2),
    ("gmm", 8192, 32, 64, 2),
    ("pca", 8192, 128, 64, 8),
]
SCHED_ENV = Environment(name="node8", n_workers=8, dispatch_overhead_s=1e-5)


def bench_schedules(results: dict, checks: list, verbose=True):
    rows = []
    for algo, n, m, p_r, p_c in SCHED_CASES:
        X, y = gaussian_blobs(n, m, seed=7)
        ex = TaskExecutor(SCHED_ENV)
        run_algo(algo, ex, DistArray.from_array(X, p_r, p_c), y)
        s = ex.stats()
        # sim_time = min(dag, barrier) + overhead enforces the never-worse
        # guarantee by construction; this check documents it holding in the
        # artifact.  The raw list schedule is recorded too -- greedy list
        # scheduling is NOT dominant (pca 64x8 prices a fraction over the
        # barrier), which is exactly why the runtime takes the min.  A
        # genuine scheduler regression shows up in the >=10% improvement
        # check below collapsing, not here.
        if s["sim_time"] > s["barrier_time"] + 1e-12:
            checks.append(f"{algo} {p_r}x{p_c}: sim_time exceeds the "
                          "barrier schedule (never-worse guarantee broken)")
        impr = 1.0 - s["sim_time"] / s["barrier_time"]
        rows.append({
            "algo": algo, "shape": [n, m], "partition": [p_r, p_c],
            "n_tasks": s["n_tasks"], "epochs": s["epochs"],
            "barrier_makespan_s": s["barrier_time"],
            "dag_raw_makespan_s": s["dag_time"],
            "dag_makespan_s": s["sim_time"],
            "improvement": impr,
        })
        csv_row(f"taskgraph/sched_{algo}_{p_r}x{p_c}",
                s["sim_time"] * 1e6,
                f"barrier={s['barrier_time']*1e6:.0f}us;impr={impr:.0%}")
    best = max(r["improvement"] for r in rows)
    if best < 0.10:
        checks.append(f"expected >=10% improvement on a fine-partitioned "
                      f"case, best was {best:.1%}")
    results["schedule"] = rows
    results["schedule_best_improvement"] = best


# The reuse and exhaustive sweeps time their cells in separate runs, so
# the argmin-identity check needs a grid whose winner is structurally
# separated, not decided by measurement jitter on near-tied cells.  These
# row-only sweeps under a per-task memory budget have exactly that shape:
# coarse cells OOM, and among the survivors the dispatch-overhead model (a
# deterministic per-task cost) separates consecutive cells ~2x, so the
# argmin is the coarsest memory-feasible partitioning -- the paper's
# overhead-vs-memory tension -- by an ~80-90% margin.  best-of-3 per task
# body additionally damps duration noise identically in both paths.
REUSE_CASES = [("kmeans", 32768, 16, 4.0), ("gmm", 8192, 32, 2.5)]


def bench_gridsearch_reuse(results: dict, checks: list, verbose=True):
    rows = []
    for algo, n, m, mem_limit in REUSE_CASES:
        X, y = gaussian_blobs(n, m, seed=0)
        env = Environment(name="node8", n_workers=8,
                          dispatch_overhead_s=1e-3, mem_limit_mb=mem_limit)

        t0 = time.perf_counter()
        log_ex, g_ex = grid_search(X, y, algo, env, mult=2, row_only=True,
                                   task_repeats=3)
        t_ex = time.perf_counter() - t0

        t0 = time.perf_counter()
        log_re, g_re = grid_search(X, y, algo, env, mult=2, row_only=True,
                                   task_repeats=3, reuse_measurements=True)
        t_re = time.perf_counter() - t0

        st_ex, st_re = grid_stats(g_ex), grid_stats(g_re)
        if st_ex["best_part"] != st_re["best_part"]:
            checks.append(f"{algo}: reuse argmin {st_re['best_part']} != "
                          f"exhaustive argmin {st_ex['best_part']}")
        executed = sum(r.meta.get("tasks", 0) - r.meta.get("replayed", 0)
                       for r in log_re.records)
        replayed = sum(r.meta.get("replayed", 0) for r in log_re.records)
        speedup = t_ex / t_re
        if speedup < 3.0:
            checks.append(f"{algo}: measurement reuse only {speedup:.2f}x "
                          "(expected >=3x)")
        rows.append({
            "algo": algo, "shape": [n, m], "cells": len(g_re),
            "exhaustive_wall_s": t_ex, "reuse_wall_s": t_re,
            "speedup_x": speedup,
            "argmin_exhaustive": list(st_ex["best_part"]),
            "argmin_reuse": list(st_re["best_part"]),
            "tasks_executed": executed, "tasks_replayed": replayed,
        })
        csv_row(f"taskgraph/grid_exhaustive_{algo}", t_ex * 1e6,
                f"cells={len(g_ex)}")
        csv_row(f"taskgraph/grid_reuse_{algo}", t_re * 1e6,
                f"speedup={speedup:.1f}x;replayed={replayed}")
    results["gridsearch_reuse"] = rows


def run(verbose=True):
    """Measure, then verify: the JSON artifact is always written (all
    measurements are recorded, plus the acceptance-check verdicts) before
    any failed check raises, so a noisy host still yields inspectable
    numbers."""
    results: dict = {}
    checks: list[str] = []
    bench_schedules(results, checks, verbose)
    bench_gridsearch_reuse(results, checks, verbose)
    results["checks_failed"] = checks
    OUT.write_text(json.dumps(results, indent=2) + "\n")
    if verbose:
        print(f"# wrote {OUT}")
    if checks:
        raise AssertionError("taskgraph bench checks failed: "
                             + "; ".join(checks))
    return results


if __name__ == "__main__":
    run()
