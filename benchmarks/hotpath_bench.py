"""Hot-path microbenchmarks for the vectorized estimation pipeline.

Measures the four paths the perf overhaul targets and writes
``BENCH_hotpath.json`` at the repo root:

  * ``fit``        -- chained-DT / forest training time on a synthetic log;
  * ``predict``    -- single-query loop vs ``predict_partitions_batch``
                      (one model pass) vs the memoized ``EstimatorService``;
  * ``gridsearch`` -- wall time and executed-cell count with and without
                      monotone OOM pruning + block-refinement reuse;
  * ``kerneltune`` -- broadcast tile-grid scoring throughput.

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

import numpy as np

from repro.core.estimator import BlockSizeEstimator, EstimatorService
from repro.core.gridsearch import grid_search, grid_stats
from repro.core.kerneltune import grid_search_matmul
from repro.core.log import ExecutionLog, ExecutionRecord
from repro.data.datasets import gaussian_blobs
from repro.data.executor import Environment

from benchmarks.common import csv_row

OUT = Path(__file__).resolve().parent.parent / "BENCH_hotpath.json"


def synthetic_log(n_sizes: int = 10, seed: int = 0) -> ExecutionLog:
    """Training log shaped like the paper's: argmin follows a clean rule."""
    log = ExecutionLog()
    rng = np.random.default_rng(seed)
    for rows in (2 ** np.arange(8, 8 + n_sizes)):
        for algo in ("kmeans", "pca", "rf", "csvm"):
            best_pr = max(1, int(rows) // 512)
            best_pc = 2 if algo in ("kmeans", "pca") else 1
            for pr in (1, 2, 4, 8, 16, 32):
                for pc in (1, 2, 4):
                    t = abs(np.log2(pr) - np.log2(best_pr)) \
                        + abs(np.log2(pc) - np.log2(best_pc)) \
                        + 0.01 * rng.random()
                    log.add(ExecutionRecord(
                        {"rows": float(rows), "cols": 64.0,
                         "log_rows": float(np.log2(rows))},
                        algo, {"n_workers": 4}, pr, pc, t))
    return log


def bench_fit(results: dict, verbose=True):
    log = synthetic_log()
    for model in ("tree", "forest"):
        t0 = time.perf_counter()
        BlockSizeEstimator(model).fit(log)
        dt = time.perf_counter() - t0
        results[f"fit_{model}_s"] = dt
        csv_row(f"hotpath/fit_{model}", dt * 1e6, "chained_cascade")


def _best_of(fn, reps: int = 3):
    """(min wall time, last result) -- min damps scheduler noise."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_predict(results: dict, verbose=True, n_queries: int = 1024):
    est = BlockSizeEstimator("tree").fit(synthetic_log())
    rng = np.random.default_rng(1)
    qs = [(int(2 ** rng.integers(8, 16)), 64,
           ("kmeans", "pca", "rf", "csvm")[int(rng.integers(4))],
           {"n_workers": 4}) for _ in range(n_queries)]

    t_loop, loop = _best_of(
        lambda: [est.predict_partitions(*q) for q in qs])
    t_batch, batch = _best_of(lambda: est.predict_partitions_batch(qs))
    assert batch == loop, "batched serving path diverged from per-row path"

    svc = EstimatorService(est)
    svc.predict_partitions_batch(qs)                       # warm the memo
    t_svc, _ = _best_of(lambda: svc.predict_partitions_batch(qs))

    speedup = t_loop / t_batch
    results.update({
        "predict_queries": n_queries,
        "predict_loop_s": t_loop, "predict_batch_s": t_batch,
        "predict_service_warm_s": t_svc,
        "batch_speedup_x": speedup,
        "service_hit_rate": svc.hit_rate,
    })
    csv_row("hotpath/predict_loop", t_loop / n_queries * 1e6, "per_query")
    csv_row("hotpath/predict_batch", t_batch / n_queries * 1e6,
            f"speedup={speedup:.1f}x")
    csv_row("hotpath/predict_service_warm", t_svc / n_queries * 1e6,
            f"hit_rate={svc.hit_rate:.2f}")


def bench_grid_generation(results: dict, verbose=True):
    """Partitioning cost alone: re-slicing the source at every cell vs one
    slice + view-refinement chains (``DistArray.refine``)."""
    from repro.core.gridsearch import _refined_cells, grid_powers
    from repro.data.distarray import DistArray

    X = np.zeros((8192, 512))                          # 32 MB source
    ps = grid_powers(8, s=2, mult=4)                   # 1..32 -> 36 cells

    t0 = time.perf_counter()
    slice_cells = {(pr, pc): DistArray.from_array(X, pr, pc)
                   for pr in ps for pc in ps}
    t_slice = time.perf_counter() - t0

    t0 = time.perf_counter()
    view_cells = _refined_cells(X, ps, ps)
    t_view = time.perf_counter() - t0

    assert set(slice_cells) == set(view_cells)
    for key in ((1, 1), (4, 8), (32, 32)):             # spot-check shapes
        assert slice_cells[key].block_shape == view_cells[key].block_shape

    results.update({
        "gen_cells": len(view_cells),
        "gen_reslice_s": t_slice, "gen_refine_s": t_view,
        "gen_speedup_x": t_slice / t_view,
    })
    csv_row("hotpath/grid_gen_reslice", t_slice * 1e6,
            f"cells={len(slice_cells)}")
    csv_row("hotpath/grid_gen_refine", t_view * 1e6,
            f"speedup={t_slice / t_view:.1f}x")


def bench_gridsearch(results: dict, verbose=True):
    """Full sweep under a tight memory budget: pruned cells are recorded
    ``inf`` without execution, and the argmin label is unchanged."""
    X, y = gaussian_blobs(2048, 32, seed=0)
    env = Environment(n_workers=8, mem_limit_mb=0.3)   # coarse cells OOM

    t0 = time.perf_counter()
    log_base, g_base = grid_search(X, y, "kmeans", env, mult=1,
                                   prune_oom=False, reuse_blocks=False)
    t_base = time.perf_counter() - t0

    t0 = time.perf_counter()
    log_fast, g_fast = grid_search(X, y, "kmeans", env, mult=1,
                                   prune_oom=True, reuse_blocks=True)
    t_fast = time.perf_counter() - t0

    pruned = sum(1 for r in log_fast.records if r.meta.get("pruned"))
    executed = len(log_fast.records) - pruned
    assert pruned > 0, "bench config must exercise OOM pruning"
    assert set(g_base) == set(g_fast)
    assert {k for k, v in g_base.items() if math.isfinite(v)} \
        == {k for k, v in g_fast.items() if math.isfinite(v)}
    assert grid_stats(g_base)["best_part"] == grid_stats(g_fast)["best_part"]

    results.update({
        "grid_cells": len(g_fast), "grid_pruned_cells": pruned,
        "grid_executed_cells": executed,
        "grid_unpruned_s": t_base, "grid_pruned_s": t_fast,
        "grid_argmin": list(grid_stats(g_fast)["best_part"]),
    })
    csv_row("hotpath/grid_unpruned", t_base * 1e6,
            f"cells={len(g_base)};executed={len(g_base)}")
    csv_row("hotpath/grid_pruned", t_fast * 1e6,
            f"executed={executed};pruned={pruned}")


def bench_kerneltune(results: dict, verbose=True):
    t0 = time.perf_counter()
    n_grids = 50
    for i in range(n_grids):
        grid_search_matmul(1024 << (i % 3), 1024, 2048)
    dt = time.perf_counter() - t0
    results["kernel_grid_us"] = dt / n_grids * 1e6
    csv_row("hotpath/kernel_tile_grid", dt / n_grids * 1e6,
            "broadcast_cost_model;bk_swept")


def run(verbose=True):
    results: dict = {}
    bench_fit(results, verbose)
    bench_predict(results, verbose)
    bench_grid_generation(results, verbose)
    bench_gridsearch(results, verbose)
    bench_kerneltune(results, verbose)
    OUT.write_text(json.dumps(results, indent=2) + "\n")
    if verbose:
        print(f"# wrote {OUT}")
    return results


if __name__ == "__main__":
    run()
