"""Ablation over the learning model (paper §III rationale):
chained DTs (paper) vs independent DTs vs regression baseline vs the
beyond-paper chained random forest -- evaluated on held-out grid-search
logs by exact-argmin hit-rate and realized makespan ratio."""
from __future__ import annotations

import math

import numpy as np

from repro.core.estimator import BlockSizeEstimator
from repro.core.gridsearch import grid_search, grid_stats
from repro.data.datasets import gaussian_blobs

from benchmarks.common import ENV64, build_training_log, csv_row

HELD_OUT = [(3072, 40, "kmeans"), (1536, 80, "rf"), (768, 160, "kmeans"),
            (6144, 20, "rf")]


def run(verbose: bool = True):
    log = build_training_log(verbose=verbose)
    # pre-compute held-out grids once (they are real executions)
    grids = {}
    for i, (n, m, algo) in enumerate(HELD_OUT):
        X, y = gaussian_blobs(n, m, seed=900 + i)
        _, grid = grid_search(X, y, algo, ENV64, mult=1)
        grids[(n, m, algo)] = grid
    out = {}
    for model in ("tree", "forest", "independent", "regression"):
        est = BlockSizeEstimator(model).fit(log)
        hits, ratios = [], []
        for (n, m, algo), grid in grids.items():
            st = grid_stats(grid)
            pr, pc = est.predict_partitions(n, m, algo, ENV64.features())
            t = grid.get((pr, pc), float("inf"))
            if math.isinf(t):
                t = st["worst"]
            hits.append((pr, pc) == st["best_part"])
            ratios.append(st["avg"] / t)
        out[model] = {"hit_rate": float(np.mean(hits)),
                      "ratio_avg": float(np.mean(ratios))}
        csv_row(f"ablation/{model}", 0.0,
                f"hit_rate={out[model]['hit_rate']:.2f};"
                f"ratio_avg={out[model]['ratio_avg']:.2f}")
    return out


if __name__ == "__main__":
    run()
