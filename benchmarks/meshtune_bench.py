"""Beyond-paper benchmark: the chained-DT cascade predicting (dp, mb) mesh
factorizations for the assigned LM cells, evaluated leave-one-arch-out with
makespan ratios against the modeled grid (the paper's Table III protocol at
the TPU layer)."""
from __future__ import annotations

import math

import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.meshtune import MeshTuner, grid_search_cell, tune_all

from benchmarks.common import csv_row


def run(chips: int = 256, verbose: bool = True):
    rows = []
    for held in ARCH_IDS:
        train_archs = [a for a in ARCH_IDS if a != held]
        log, _ = tune_all(train_archs, chips=chips)
        tuner = MeshTuner(chips).fit(log)
        cfg = get_config(held)
        for sn in ("train_4k", "prefill_32k", "decode_32k"):
            if sn in cfg.skip_shapes:
                continue
            _, grid = grid_search_cell(cfg, SHAPES[sn], chips=chips)
            finite = {k: v for k, v in grid.items() if math.isfinite(v)}
            if not finite:
                continue
            best = min(finite.values())
            worst = max(finite.values())
            avg = float(np.mean(list(finite.values())))
            dp, tp, mb = tuner.predict(cfg, SHAPES[sn])
            t = grid.get((dp, mb), float("inf"))
            if math.isinf(t):
                t = worst
            rows.append({"arch": held, "shape": sn, "pred": (dp, tp, mb),
                         "t": t, "best": best, "avg": avg, "worst": worst,
                         "ratio_best": t / best, "ratio_avg": avg / t,
                         "ratio_worst": worst / t})
    r_best = float(np.mean([r["ratio_best"] for r in rows]))
    r_avg = float(np.mean([r["ratio_avg"] for r in rows]))
    r_worst = float(np.mean([r["ratio_worst"] for r in rows]))
    csv_row("meshtune/loo_avg", 0.0,
            f"t_over_best={r_best:.2f};ratio_avg={r_avg:.2f};"
            f"ratio_worst={r_worst:.2f};cells={len(rows)}")
    if verbose:
        for r in rows:
            print(f"  meshtune {r['arch']:20s} {r['shape']:12s} "
                  f"pred=dp{r['pred'][0]}/tp{r['pred'][1]}/mb{r['pred'][2]} "
                  f"t/best={r['ratio_best']:.2f}")
    return rows


if __name__ == "__main__":
    run()
