"""Chaos bench for the fault-tolerant elastic runtime (DESIGN.md §11):
seeded failure injection across all three tiers, with the recovery
contracts asserted and gated.

Writes ``BENCH_fault.json`` at the repo root:

  * structural contracts the CI gate checks exactly — recovered results
    bit-identical to the fault-free run, zero requests lost and zero
    staleness violations under a shard-worker crash, one injected crash
    and one respawn, the refit daemon resuming from its durable cursor;
  * banded metrics — at least one lineage re-execution, recovery beating
    restart-from-scratch (task-graph and elastic tiers), at least one
    ring re-route;
  * recorded-only wall-clock and event details (never gated).

Three scenarios, each fully seeded:

  A. **Task-graph chaos** — a kmeans DAG under a ``FaultPlan``: one
     worker lost mid-run (at a fraction of the *measured* fault-free
     makespan, retried across fractions until the loss catches a task in
     flight), one worker slowed with a straggler detector watching, and
     transient failures retried through the real ``RetryPolicy``.  The
     recovered result must be bit-identical to the fault-free run, and
     the recovery makespan must beat the restart-from-scratch baseline
     (loss time + the full workload re-run on the degraded pool, same
     chaos plan with the loss moved to t=0 — restart faces identical
     post-loss conditions but re-pays all pre-loss work).

  B. **Elastic scale-up** — ``AutoTunedRun.run_elastic``: the
     environment grows mid-run, the estimator is re-queried, the
     in-flight ``DistArray`` live-repartitions by ``refine`` (views, no
     copies), and the finished run must match the restart baseline's
     result while beating its time.

  C. **Serving chaos** — a shard worker crashes *holding a batch* under
     closed-loop load: the router respawns the shard and ring-re-routes
     every orphaned request (zero lost, zero staleness violations even
     with a concurrent model swap); a request past its deadline is
     dropped unserved with ``DeadlineExceeded``; the refit daemon is
     "crashed" and a replacement resumes from the persisted cursor.

Usage:
  python -m benchmarks.fault_bench --smoke     # what CI runs (default)
  python -m benchmarks.fault_bench --full      # more load, more rounds

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.algorithms import kmeans as kmeans_mod
from repro.core.estimator import BlockSizeEstimator
from repro.core.gridsearch import grid_search
from repro.data.datasets import gaussian_blobs
from repro.data.distarray import DistArray
from repro.data.executor import Environment, TaskExecutor
from repro.data.logstore import LogStore
from repro.eval.autorun import AutoTunedRun, EnvChange
from repro.runtime.fault import (FaultPlan, RetryPolicy, Slowdown,
                                 StragglerConfig, WorkerLoss)
from repro.serve import (DeadlineExceeded, RefitDaemon, ShardRouter,
                         make_trace, run_load)

from benchmarks.common import csv_row

OUT = Path(__file__).resolve().parent.parent / "BENCH_fault.json"

ENV4 = Environment(name="laptop", n_workers=4, n_nodes=1,
                   mem_limit_mb=2048.0, dispatch_overhead_s=1e-4, ram_gb=16)
ENV8 = Environment(name="laptop8", n_workers=8, n_nodes=1,
                   mem_limit_mb=2048.0, dispatch_overhead_s=1e-4, ram_gb=16)
SHAPES = ((256, 16), (512, 16), (1024, 32), (192, 12), (96, 24), (48, 8))

# loss times to try, as fractions of the measured fault-free makespan --
# the first fraction that catches a task in flight on the doomed worker
# (>=1 lineage re-execution) wins; a loss landing in an idle gap kills
# the worker without orphaning work, which is a weaker test.  Mid-run
# first, then a dense sweep: measured durations vary run to run, so the
# schedule's idle gaps move.
LOSS_FRACTIONS = (0.5, 0.35, 0.65, 0.2, 0.8, 0.45, 0.3, 0.6, 0.25, 0.7,
                  0.4, 0.55, 0.15, 0.75, 0.1)
# straggler onsets to try (same reasoning: the slowed worker needs a few
# healthy completions first, and the epochs drift with measured timings)
ONSET_FRACTIONS = (0.3, 0.5, 0.2, 0.4, 0.1)


def _kmeans_chaos(X, plan, env, iters):
    ex = TaskExecutor(env, fault_plan=plan)
    Xd = DistArray.from_array(X, 2, 2)
    out = kmeans_mod.fit(ex, Xd, k=8, iters=iters, seed=0)
    return ex, out


def _assert_bit_identical(ref, out, what):
    ok = (np.array_equal(ref["centers"], out["centers"])
          and ref["inertia"] == out["inertia"]
          and all(np.array_equal(a, b)
                  for a, b in zip(ref["labels"], out["labels"])))
    assert ok, f"{what} diverged from the fault-free result"
    return ok


def scenario_taskgraph(*, iters=6, verbose=True):
    X, _ = gaussian_blobs(512, 24, seed=3)

    # fault-free reference: the results chaos must reproduce bit-for-bit,
    # and the makespan the chaos schedules are anchored to
    ex0 = TaskExecutor(ENV4)
    ref = kmeans_mod.fit(ex0, DistArray.from_array(X, 2, 2), k=8,
                         iters=iters, seed=0)
    t_free = ex0.sim_time
    retry = RetryPolicy(max_retries=3, backoff_s=1e-4, jitter=0.1, seed=0)

    # ---- worker loss + transients: lineage recovery vs restart
    chosen = None
    for frac in LOSS_FRACTIONS:
        plan = FaultPlan(losses=(WorkerLoss(1, frac * t_free),),
                         transient={3: 1, 11: 2}, retry=retry)
        ex, out = _kmeans_chaos(X, plan, ENV4, iters)
        fs = ex.fault_stats()
        if fs["reexecuted_tasks"] >= 1:
            chosen = (frac, ex, out, fs)
            break
    assert chosen is not None, \
        f"no loss fraction in {LOSS_FRACTIONS} caught a task in flight"
    frac, ex, out, fs = chosen
    t_loss = frac * t_free
    bit_identical = _assert_bit_identical(ref, out, "loss-chaos run")
    assert fs["lost_workers"] == [1], fs
    assert fs["transient_retries"] >= 1, fs

    # restart-from-scratch baseline: throw away everything done before the
    # loss and re-run the whole workload on the degraded pool (same chaos
    # plan, loss moved to t=0 so the pool is degraded throughout -- the
    # conditions recovery faced after the loss, minus the saved work)
    plan_restart = FaultPlan(losses=(WorkerLoss(1, 0.0),),
                             transient=plan.transient, retry=retry)
    ex_r, out_r = _kmeans_chaos(X, plan_restart, ENV4, iters)
    recovery_s = ex.sim_time
    restart_s = t_loss + ex_r.sim_time
    speedup = restart_s / max(recovery_s, 1e-12)
    _assert_bit_identical(ref, out_r, "restart-baseline run")

    # ---- slowdown + straggler detector: quarantine on normalized timings
    straggler = StragglerConfig(window=16, threshold=2.0, patience=2,
                                warmup=3)
    quarantined, slow_events = [], []
    for onset in ONSET_FRACTIONS:
        plan_slow = FaultPlan(slowdowns=(Slowdown(2, 6.0,
                                                  after=onset * t_free),),
                              straggler=straggler)
        ex_s, out_s = _kmeans_chaos(X, plan_slow, ENV4, iters)
        fs_s = ex_s.fault_stats()
        _assert_bit_identical(ref, out_s, "slowdown run")
        if fs_s["quarantined_workers"]:
            quarantined = fs_s["quarantined_workers"]
            slow_events = fs_s["events"]
            break
    assert quarantined == [2], \
        f"straggler never quarantined at onsets {ONSET_FRACTIONS}"

    res = {
        "bit_identical": bool(bit_identical),
        "reexecuted": fs["reexecuted_tasks"],
        "lost_workers": fs["lost_workers"],
        "quarantined": len(quarantined),
        "quarantined_workers": quarantined,
        "transient_retries": fs["transient_retries"],
        "retry_delay_s": fs["retry_delay_s"],
        "loss_fraction": frac,
        "faultfree_makespan_s": t_free,
        "recovery_makespan_s": recovery_s,
        "restart_makespan_s": restart_s,
        "recovery_speedup": speedup,
        "events": fs["events"] + slow_events,
    }
    csv_row("fault/taskgraph", recovery_s * 1e6,
            f"reexec={fs['reexecuted_tasks']};retries="
            f"{fs['transient_retries']};quarantined={len(quarantined)};"
            f"speedup={speedup:.2f};bitident={bit_identical}")
    if verbose:
        print(f"# taskgraph chaos: loss@{frac:.2f}*T, "
              f"{fs['reexecuted_tasks']} reexecuted, quarantined "
              f"{quarantined}, speedup {speedup:.2f}")
    return res


def scenario_elastic(*, iters=6, verbose=True):
    X, y = gaussian_blobs(256, 16, seed=5)
    est = BlockSizeEstimator("tree")          # unfit -> default heuristic,
    loop = AutoTunedRun(est, None, refit=False)  # fully deterministic grids
    r = loop.run_elastic(X, y, "kmeans", ENV4,
                         EnvChange(after_iter=iters // 2, env=ENV8,
                                   reason="scale-up"),
                         iters=iters)
    assert r.repartition == "refine", r.repartition
    assert r.results_close, "recovered centers != restarted centers"
    assert r.speedup > 1.0, f"recovery did not beat restart: {r.speedup}"
    res = {
        "partitions": r.partitions,
        "repartition": r.repartition,
        "repartition_s": r.repartition_s,
        "recovery_time_s": r.recovery_time_s,
        "restart_time_s": r.restart_time_s,
        "speedup": r.speedup,
        "results_close": bool(r.results_close),
        "record_source_recovery": bool(r.record.meta.get("recovery")),
    }
    csv_row("fault/elastic", r.recovery_time_s * 1e6,
            f"{r.partitions[0]}->{r.partitions[1]};{r.repartition};"
            f"speedup={r.speedup:.2f};close={r.results_close}")
    if verbose:
        print(f"# elastic scale-up: {r.partitions[0]} -> {r.partitions[1]} "
              f"via {r.repartition}, speedup {r.speedup:.2f}")
    return res


def _sweep(store, algo, n, m, seed):
    X, y = gaussian_blobs(n, m, seed=seed)
    grid_search(X, y, algo, ENV4, mult=1, reuse_measurements=True,
                store=store)


def scenario_serving(*, requests=300, n_clients=4, n_shards=4, seed=0,
                     verbose=True):
    t0 = time.time()
    with tempfile.TemporaryDirectory() as tmp:
        store = LogStore(Path(tmp) / "fault_store.jsonl")
        _sweep(store, "kmeans", 256, 16, seed=7)
        est = BlockSizeEstimator("tree").fit(store.load())
        feats = ENV4.features()
        universe = [(n, m, "kmeans", feats) for n, m in SHAPES]

        # ---- crash under load: the hot key's shard dies holding a batch
        router = ShardRouter(est, n_shards=n_shards, queue_depth=256,
                             admission="block", window_s=0.001)
        trace = make_trace(requests, universe, seed=seed)
        router.inject_crash(router.shard_for(trace[0][1]), after_batches=2)
        rep = run_load(router, trace, n_clients=n_clients)
        stats = router.stats()
        lost = rep["requests"] - rep["served"]
        assert lost == 0 and rep["errors"] == 0, \
            f"requests lost under crash: {lost} ({rep['first_error']})"
        assert rep["staleness_violations"] == 0
        assert stats["crashes"] == 1 and stats["respawns"] == 1, stats
        assert stats["rerouted"] >= 1, stats
        assert stats["served"] == rep["requests"], \
            "retired crashed-shard counters dropped from totals"

        # ---- deadline: an already-expired request is dropped unserved
        expired_raised = False
        try:
            router.request(universe[0], deadline_s=-1e-3)
        except DeadlineExceeded:
            expired_raised = True
        assert expired_raised
        served_after = router.request(universe[0], deadline_s=30.0)
        assert served_after.value is not None
        expired = router.stats()["expired"]
        assert expired == 1, expired
        router.close()

        # ---- refit daemon crash/restart from the durable cursor
        est2 = BlockSizeEstimator("tree").fit(store.load())
        router = ShardRouter(est2, n_shards=2, window_s=0.001)
        cursor_file = Path(tmp) / "refit.cursor"
        d1 = RefitDaemon(router, store, cursor_path=cursor_file)
        _sweep(store, "pca", 256, 16, seed=9)   # new algo -> must retrain
        daemon_swapped = d1.poll_once()
        assert daemon_swapped and d1.swaps == 1, (daemon_swapped, d1.swaps)
        persisted = json.loads(cursor_file.read_text())["cursor"]
        assert persisted == d1.cursor == len(store)
        # "crash" d1 (just stop referencing it) and restart from the file
        d2 = RefitDaemon(router, store, cursor_path=cursor_file)
        daemon_resumed = d2.cursor == persisted
        _sweep(store, "gmm", 192, 12, seed=8)   # post-restart learning works
        resumed_swap = d2.poll_once()
        assert daemon_resumed and resumed_swap, (daemon_resumed, resumed_swap)
        assert d2.cursor == len(store)
        router.close()

    res = {
        "requests": rep["requests"],
        "served": rep["served"],
        "lost_requests": lost,
        "staleness_violations": rep["staleness_violations"],
        "crashes": stats["crashes"],
        "respawns": stats["respawns"],
        "rerouted": stats["rerouted"],
        "expired": expired,
        "daemon_swapped": bool(daemon_swapped),
        "daemon_resumed": bool(daemon_resumed),
        "daemon_resumed_swap": bool(resumed_swap),
        "throughput_rps": rep["throughput_rps"],
        "p99_ms": rep["p99_ms"],
        "wall_s": time.time() - t0,
    }
    csv_row("fault/serving", rep["wall_s"] / max(rep["served"], 1) * 1e6,
            f"lost={lost};stale={rep['staleness_violations']};"
            f"crashes={stats['crashes']};rerouted={stats['rerouted']};"
            f"expired={expired}")
    if verbose:
        print(f"# serving chaos: {rep['served']}/{rep['requests']} served, "
              f"{stats['rerouted']} rerouted, daemon resumed="
              f"{daemon_resumed}")
    return res


def run(verbose=True, *, iters=6, requests=300, n_clients=4, n_shards=4,
        seed=0):
    t0 = time.time()
    results = {
        "taskgraph": scenario_taskgraph(iters=iters, verbose=verbose),
        "elastic": scenario_elastic(iters=iters, verbose=verbose),
        "serving": scenario_serving(requests=requests, n_clients=n_clients,
                                    n_shards=n_shards, seed=seed,
                                    verbose=verbose),
    }
    results["wall_s"] = time.time() - t0
    OUT.write_text(json.dumps(results, indent=2) + "\n")
    if verbose:
        print(f"# wrote {OUT}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description="fault-tolerance chaos bench")
    ap.add_argument("--smoke", action="store_true",
                    help="the fast CI configuration (this is the default)")
    ap.add_argument("--full", action="store_true",
                    help="more load: longer runs, more clients")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    requests = args.requests or (1000 if args.full else 300)
    clients = args.clients or (8 if args.full else 4)
    iters = 10 if args.full else 6
    print("name,us_per_call,derived")
    return run(iters=iters, requests=requests, n_clients=clients,
               n_shards=args.shards, seed=args.seed)


if __name__ == "__main__":
    main()
