"""Load test + correctness asserts for the online serving subsystem
(src/repro/serve/): sharded router, background refit daemon, closed-loop
load generator.

Writes ``BENCH_serving.json`` at the repo root:

  * structural counts the CI gate checks exactly — shard count, requests
    served, zero rejected-under-capacity, zero staleness violations, the
    deterministic set of traffic-active shards;
  * banded metrics — memo hit rate, refit swaps, invalidations;
  * recorded-only wall-clock — throughput and p50/p95/p99 latency
    (never gated; CI runners vary wildly in absolute speed).

The scenario is the paper's deployment story under concurrency: warm the
estimator from a grid-swept store, serve round 1 of a seeded hot/zipf/
uniform/cold query mix from K client threads (the cold algorithm
abstains to the default heuristic), then sweep the cold algorithm into
the store so the refit daemon folds it and atomically swaps the model
in, and serve later rounds — with a concurrent writer churning the store
mid-round — asserting that **no request enqueued after a swap is ever
served by the old model** and that the previously-cold algorithm is now
answered by the model.

Usage:
  python -m benchmarks.serving_bench --smoke     # what CI runs (default)
  python -m benchmarks.serving_bench --full      # nightly multi-round run

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import argparse
import json
import math
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.core.estimator import BlockSizeEstimator
from repro.core.gridsearch import grid_search
from repro.data.datasets import gaussian_blobs
from repro.data.executor import Environment
from repro.data.logstore import LogStore
from repro.serve import RefitDaemon, ShardRouter, make_trace, run_load

from benchmarks.common import csv_row

OUT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"

ENV = Environment(name="laptop", n_workers=4, n_nodes=1, mem_limit_mb=2048.0,
                  dispatch_overhead_s=1e-4, ram_gb=16)
# shapes chosen to land on distinct power-of-two memo buckets, so the
# consistent-hash ring spreads the keys over several shards
SHAPES = ((256, 16), (512, 16), (1024, 32), (192, 12), (96, 24), (48, 8))
COLD_ALGO = "pca"            # swept into the store between rounds 1 and 2
LATE_COLD_ALGO = "rf"        # never swept: keeps the abstain path live


def _sweep(store, algo, n, m, seed):
    X, y = gaussian_blobs(n, m, seed=seed)
    grid_search(X, y, algo, ENV, mult=1, reuse_measurements=True,
                store=store)


def _universe(algos):
    feats = ENV.features()
    return [(n, m, a, feats) for a in algos for n, m in SHAPES]


def run(verbose=True, *, rounds=2, requests_per_round=240, n_clients=4,
        n_shards=4, seed=0):
    assert rounds >= 2, "need a pre-swap and a post-swap round"
    t0 = time.time()
    with tempfile.TemporaryDirectory() as tmp:
        store = LogStore(Path(tmp) / "serve_store.jsonl")
        _sweep(store, "kmeans", 256, 16, seed=7)
        _sweep(store, "gmm", 192, 12, seed=8)
        est = BlockSizeEstimator("tree").fit(store.load())
        router = ShardRouter(est, n_shards=n_shards, queue_depth=256,
                             admission="reject", window_s=0.001)
        daemon = RefitDaemon(router, store, interval_s=0.02).start()
        try:
            feats = ENV.features()
            reports = []

            # ---- round 1: COLD_ALGO unknown -> abstain/default everywhere
            trace = make_trace(requests_per_round, _universe(("kmeans",
                                                              "gmm")),
                               seed=seed,
                               cold_queries=[(256, 16, COLD_ALGO, feats)])
            reports.append(run_load(router, trace, n_clients=n_clients,
                                    include_latencies=True))
            assert reports[0]["by_kind"]["cold"]["default_frac"] == 1.0, \
                f"cold algo served by the model pre-refit: {reports[0]}"

            # ---- churn: sweep the cold algo; the daemon folds + swaps
            _sweep(store, COLD_ALGO, 256, 16, seed=9)
            deadline = time.time() + 30
            while daemon.swaps < 1 and time.time() < deadline:
                time.sleep(0.01)
            assert daemon.swaps >= 1, \
                f"refit daemon never swapped (last_error={daemon.last_error})"
            res = router.request((256, 16, COLD_ALGO, feats))
            assert res.chosen_by == "model", \
                f"{COLD_ALGO} still abstains after the swap: {res}"

            # ---- rounds 2..N: swapped model serves; a concurrent writer
            # keeps churning the store mid-round
            uni2 = _universe(("kmeans", "gmm", COLD_ALGO))
            for ri in range(1, rounds):
                writer = threading.Thread(
                    target=_sweep,
                    args=(store, "csvm", 128 + 64 * ri, 8, 20 + ri),
                    daemon=True)
                writer.start()
                trace = make_trace(
                    requests_per_round, uni2, seed=seed + ri,
                    cold_queries=[(256, 16, LATE_COLD_ALGO, feats)])
                reports.append(run_load(router, trace, n_clients=n_clients,
                                        include_latencies=True))
                writer.join()
            swaps = daemon.swaps
        finally:
            daemon.stop()
            router.close()
        stats = router.stats()

    # ---------------------------------------------------------- aggregate
    lat_ms = np.concatenate([r["latencies_ms"] for r in reports])
    requests = sum(r["requests"] for r in reports)
    served = sum(r["served"] for r in reports)
    rejected = sum(r["rejected"] for r in reports)
    stale = sum(r["staleness_violations"] for r in reports)
    wall = sum(r["wall_s"] for r in reports)
    active = sorted(p["shard"] for p in stats["per_shard"] if p["served"])

    # the asserts the smoke suite (and --smoke CLI) lives or dies on
    assert stale == 0, f"{stale} staleness violations across refit swaps"
    assert rejected == 0, \
        f"{rejected} requests dropped under capacity (depth 256)"
    errors = [r["first_error"] for r in reports if r["errors"]]
    assert not errors, f"serving errors during load: {errors}"
    assert served == requests, (served, requests)
    assert stats["invalidations"] >= 1, \
        f"swap never flushed a serving memo: {stats}"
    p99 = float(np.percentile(lat_ms, 99))
    throughput = served / wall
    assert math.isfinite(p99) and p99 > 0.0
    assert throughput > 0.0

    results = {
        "n_shards": n_shards,
        "n_shards_active": len(active),
        "active_shards": active,
        "rounds": rounds,
        "requests": requests,
        "served": served,
        "rejected": rejected,
        "staleness_violations": stale,
        "refit_swaps": swaps,
        "invalidations": stats["invalidations"],
        "hit_rate": stats["hit_rate"],
        "abstained": stats["abstained"],
        "cold_round1_default_frac":
            reports[0]["by_kind"]["cold"]["default_frac"],
        "cold_after_swap_chosen_by": res.chosen_by,
        "throughput_rps": throughput,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p95_ms": float(np.percentile(lat_ms, 95)),
        "p99_ms": p99,
        "wall_s": time.time() - t0,
        "per_shard": stats["per_shard"],
        "per_round": [{k: r[k] for k in
                       ("requests", "served", "rejected", "throughput_rps",
                        "p50_ms", "p99_ms", "staleness_violations")}
                      for r in reports],
    }
    OUT.write_text(json.dumps(results, indent=2) + "\n")

    csv_row("serving/load", wall / max(served, 1) * 1e6,
            f"rps={throughput:.0f};p99={p99:.2f}ms;"
            f"hit={stats['hit_rate']:.2f};stale={stale};swaps={swaps}")
    csv_row("serving/refit_swap", results["wall_s"] * 1e6,
            f"shards={n_shards};invalidations={stats['invalidations']};"
            f"cold={COLD_ALGO}:{res.chosen_by}")
    if verbose:
        print(f"# wrote {OUT}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description="serving-tier load test")
    ap.add_argument("--smoke", action="store_true",
                    help="the fast CI configuration (this is the default)")
    ap.add_argument("--full", action="store_true",
                    help="nightly scale: more rounds, requests, clients")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rounds = args.rounds or (4 if args.full else 2)
    requests = args.requests or (1000 if args.full else 240)
    clients = args.clients or (8 if args.full else 4)
    print("name,us_per_call,derived")
    return run(rounds=rounds, requests_per_round=requests,
               n_clients=clients, n_shards=args.shards, seed=args.seed)


if __name__ == "__main__":
    main()
