"""Load test + correctness asserts for the online serving subsystem
(src/repro/serve/): serving fleet (replica groups, crash respawn,
rolling swaps, admission classes), background refit daemon, closed-loop
load generator.

Writes ``BENCH_serving.json`` at the repo root:

  * structural counts the CI gate checks exactly — shard count, requests
    served, zero rejected-under-capacity, zero staleness violations, the
    deterministic set of traffic-active shards, zero lost requests
    across a mid-trace worker crash;
  * banded metrics — memo hit rate, refit swaps, invalidations, served
    skew (max/mean load across serving replicas; hot-shard replication
    must hold it at ≤1.5 where the unreplicated router showed >3);
  * recorded-only wall-clock — throughput and p50/p95/p99 latency
    (never gated; CI runners vary wildly in absolute speed).

Three sections:

1. **Refit scenario** (gated): the paper's deployment story under
   concurrency — warm from a grid-swept store, serve a seeded
   hot/zipf/uniform/cold mix (the cold algorithm abstains to the default
   heuristic), sweep the cold algorithm so the refit daemon folds and
   atomically swaps, then serve more rounds with a concurrent writer —
   asserting that **no request enqueued after a swap is ever served by
   the old model**.  Runs on the fleet router (loopback transport: the
   deterministic CI path) with a demand-proportional replica plan.
2. **Diurnal fleet load** (gated): a 10⁵-request seeded diurnal trace
   with a worker crash injected on the hottest shard *and* a rolling
   model swap mid-trace — zero lost requests, zero staleness, served
   skew ≤ 1.5.  ``--full`` scales this to 5·10⁵ requests over real
   worker processes.  The **socket fleet** section (gated) reruns the
   same scenario over the TCP socket transport, where the "crash" is a
   dropped connection racing the rolling swap.  The **migration**
   section (gated) serves a shifted-hotspot trace: the replica plan is
   provisioned for the first half, the hot set jumps at half-time, and
   the autoscaler's global-budget rebalance must move replicas so the
   final window's served skew lands back ≤ 1.5.
   The **control-plane** section (gated) runs the fleet from the
   lease registry instead of a hand-typed address list: workers are
   *discovered* through a :class:`TransportSpec`, a silently-dead
   worker is replaced by the heartbeat prober before any caller
   observes an error, a late-joining registered worker is adopted by
   one ``poll_registry()``, forged/unauthenticated frames bounce with a
   typed :class:`FrameAuthError`, and a checkpoint→restore hands the
   live fleet to a replacement router mid-trace with zero lost
   requests and the staleness contract intact (a stale backend is
   refused at restore).
3. **Process-fleet speedup** (``--full`` only): a memo-defeating
   compute-heavy trace served by the single-process router vs the
   multi-process fleet; on multi-core hosts the fleet must clear 2x.

Usage:
  python -m benchmarks.serving_bench --smoke     # what CI runs (default)
  python -m benchmarks.serving_bench --full      # nightly fleet scale

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.core.estimator import BlockSizeEstimator
from repro.core.gridsearch import grid_search
from repro.data.datasets import gaussian_blobs
from repro.data.executor import Environment
from repro.data.logstore import LogStore
from repro.serve import (AutoscalePolicy, Autoscaler, FleetRouter,
                         FrameAuthError, HeartbeatPolicy, RefitDaemon,
                         ShardRouter, TransportSpec, WorkerRegistry,
                         demand_plan, make_diurnal_trace, make_trace,
                         make_transport, proportional_plan, run_load,
                         trace_histogram)

from benchmarks.common import csv_row

OUT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"

ENV = Environment(name="laptop", n_workers=4, n_nodes=1, mem_limit_mb=2048.0,
                  dispatch_overhead_s=1e-4, ram_gb=16)
# shapes chosen to land on distinct power-of-two memo buckets, so the
# consistent-hash ring spreads the keys over several shards
SHAPES = ((256, 16), (512, 16), (1024, 32), (192, 12), (96, 24), (48, 8))
COLD_ALGO = "pca"            # swept into the store between rounds 1 and 2
LATE_COLD_ALGO = "rf"        # never swept: keeps the abstain path live


def _sweep(store, algo, n, m, seed):
    X, y = gaussian_blobs(n, m, seed=seed)
    grid_search(X, y, algo, ENV, mult=1, reuse_measurements=True,
                store=store)


def _universe(algos):
    feats = ENV.features()
    return [(n, m, a, feats) for a in algos for n, m in SHAPES]


# ------------------------------------------------------ 1. refit scenario
def _refit_scenario(store, *, rounds, requests_per_round, n_clients,
                    n_shards, seed):
    assert rounds >= 2, "need a pre-swap and a post-swap round"
    est = BlockSizeEstimator("tree").fit(store.load())
    feats = ENV.features()

    # traces are deterministic, so build them all upfront and provision
    # replicas proportionally to the measured per-shard demand
    traces = [make_trace(requests_per_round, _universe(("kmeans", "gmm")),
                         seed=seed,
                         cold_queries=[(256, 16, COLD_ALGO, feats)])]
    uni2 = _universe(("kmeans", "gmm", COLD_ALGO))
    for ri in range(1, rounds):
        traces.append(make_trace(
            requests_per_round, uni2, seed=seed + ri,
            cold_queries=[(256, 16, LATE_COLD_ALGO, feats)]))
    plan = demand_plan(est, [e for t in traces for e in t], n_shards)

    router = FleetRouter(est, n_shards=n_shards, replicas=plan,
                         queue_depth=256, admission="reject",
                         window_s=0.001)
    daemon = RefitDaemon(router, store, interval_s=0.02).start()
    try:
        reports = []

        # ---- round 1: COLD_ALGO unknown -> abstain/default everywhere
        reports.append(run_load(router, traces[0], n_clients=n_clients,
                                include_latencies=True))
        assert reports[0]["by_kind"]["cold"]["default_frac"] == 1.0, \
            f"cold algo served by the model pre-refit: {reports[0]}"

        # ---- churn: sweep the cold algo; the daemon folds + swaps
        _sweep(store, COLD_ALGO, 256, 16, seed=9)
        deadline = time.time() + 30
        while daemon.swaps < 1 and time.time() < deadline:
            time.sleep(0.01)
        assert daemon.swaps >= 1, \
            f"refit daemon never swapped (last_error={daemon.last_error})"
        res = router.request((256, 16, COLD_ALGO, feats))
        assert res.chosen_by == "model", \
            f"{COLD_ALGO} still abstains after the swap: {res}"

        # ---- rounds 2..N: swapped model serves; a concurrent writer
        # keeps churning the store mid-round
        for ri in range(1, rounds):
            writer = threading.Thread(
                target=_sweep,
                args=(store, "csvm", 128 + 64 * ri, 8, 20 + ri),
                daemon=True)
            writer.start()
            reports.append(run_load(router, traces[ri],
                                    n_clients=n_clients,
                                    include_latencies=True))
            writer.join()
        swaps = daemon.swaps
        # snapshot while replicas are live: per-replica rows (and the
        # served-skew they feed) retire at close()
        stats = router.stats()
    finally:
        daemon.stop()
        router.close()

    lat_ms = np.concatenate([r["latencies_ms"] for r in reports])
    requests = sum(r["requests"] for r in reports)
    served = sum(r["served"] for r in reports)
    rejected = sum(r["rejected"] for r in reports)
    stale = sum(r["staleness_violations"] for r in reports)
    wall = sum(r["wall_s"] for r in reports)
    active = sorted(p["shard"] for p in stats["per_shard"] if p["served"])

    # the asserts the smoke suite (and --smoke CLI) lives or dies on
    assert stale == 0, f"{stale} staleness violations across refit swaps"
    assert rejected == 0, \
        f"{rejected} requests dropped under capacity (depth 256)"
    errors = [r["first_error"] for r in reports if r["errors"]]
    assert not errors, f"serving errors during load: {errors}"
    assert served == requests, (served, requests)
    assert stats["invalidations"] >= 1, \
        f"swap never flushed a serving memo: {stats}"
    p99 = float(np.percentile(lat_ms, 99))
    throughput = served / wall
    assert math.isfinite(p99) and p99 > 0.0
    assert throughput > 0.0

    total_shard = sum(p["served"] for p in stats["per_shard"]) or 1
    return {
        "n_shards": n_shards,
        "n_shards_active": len(active),
        "active_shards": active,
        "rounds": rounds,
        "requests": requests,
        "served": served,
        "rejected": rejected,
        "staleness_violations": stale,
        "refit_swaps": swaps,
        "invalidations": stats["invalidations"],
        "hit_rate": stats["hit_rate"],
        "abstained": stats["abstained"],
        "cold_round1_default_frac":
            reports[0]["by_kind"]["cold"]["default_frac"],
        "cold_after_swap_chosen_by": res.chosen_by,
        "replica_plan": {str(s): n for s, n in sorted(plan.items())},
        "n_replicas": stats["n_replicas"],
        "served_skew": stats["served_skew"],
        "per_shard_served_frac": {
            str(p["shard"]): p["served"] / total_shard
            for p in stats["per_shard"]},
        "throughput_rps": throughput,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p95_ms": float(np.percentile(lat_ms, 95)),
        "p99_ms": p99,
        "per_shard": stats["per_shard"],
        "per_round": [{k: r[k] for k in
                       ("requests", "served", "rejected", "throughput_rps",
                        "p50_ms", "p99_ms", "staleness_violations",
                        "served_skew")}
                      for r in reports],
    }


# -------------------------------------------------- 2. diurnal fleet load
def _diurnal_fleet(store, *, requests, n_clients, n_shards, seed,
                   transport, sweep_shape=(96, 24, 31)):
    """Fleet-scale diurnal trace with a worker crash on the hottest shard
    AND a rolling model swap mid-trace: zero lost requests, zero
    staleness, skew held down by demand-proportional replication."""
    est = BlockSizeEstimator("tree").fit(store.load())
    trace = make_diurnal_trace(requests, _universe(("kmeans", "gmm")),
                               seed=seed, pattern="diurnal")
    plan = demand_plan(est, trace, n_shards)
    hottest = max(plan, key=plan.get)

    # the swap target: an incremental refit on one more swept algorithm,
    # so its model_version genuinely advances past the serving model's
    cursor = len(store)
    _sweep(store, "csvm", *sweep_shape)
    new_records = [r for r, _src in store.follow(cursor)[0]]
    est_v2 = est.snapshot()
    assert est_v2.refit(new_records), "swap target did not retrain"
    assert est_v2.model_version > est.model_version

    fleet = FleetRouter(est, n_shards=n_shards, replicas=plan,
                        transport=transport, queue_depth=256,
                        admission="block", window_s=0.001,
                        call_timeout_s=120.0)
    try:
        fleet.inject_crash(hottest, after_batches=5)
        swapped = threading.Event()

        def swapper():
            # land the rolling swap mid-trace, while clients are hot
            time.sleep(0.5)
            fleet.swap(est_v2)
            swapped.set()

        th = threading.Thread(target=swapper, daemon=True)
        th.start()
        rep = run_load(fleet, trace, n_clients=n_clients, timeout=300)
        th.join(60)
        stats = fleet.stats()
    finally:
        fleet.close()

    lost = rep["requests"] - rep["served"] - rep["rejected"] - rep["expired"]
    assert swapped.is_set(), "rolling swap never completed"
    assert rep["errors"] == 0, f"serving errors: {rep['first_error']}"
    assert lost == 0, f"{lost} requests lost across crash + rolling swap"
    assert rep["staleness_violations"] == 0, \
        f"{rep['staleness_violations']} staleness violations"
    assert stats["crashes"] >= 1 and stats["respawns"] >= 1, stats
    assert rep["served_skew"] <= 1.5, \
        f"served skew {rep['served_skew']:.2f} > 1.5 despite replication"

    return {
        "transport": transport,
        "requests": rep["requests"],
        "served": rep["served"],
        "lost": lost,
        "errors": rep["errors"],
        "staleness_violations": rep["staleness_violations"],
        "crashes": stats["crashes"],
        "respawns": stats["respawns"],
        "rerouted": stats["rerouted"],
        "swaps": stats["swaps"],
        "served_skew": rep["served_skew"],
        "served_units": rep["served_units"],
        "replica_plan": {str(s): n for s, n in sorted(plan.items())},
        "crash_shard": hottest,
        "throughput_rps": rep["throughput_rps"],
        "p50_ms": rep["p50_ms"],
        "p99_ms": rep["p99_ms"],
        "wall_s": rep["wall_s"],
    }


# ------------------------------------- 2b. replica migration under shift
def _migration_fleet(store, *, requests, n_clients, n_shards, seed):
    """Shifted-hotspot trace against the global-budget rebalancer: the
    replica plan is provisioned for the *first half* of the trace, then
    the hot set jumps at half-time and the autoscaler's ``rebalance()``
    must *move* replicas (drain cold shard → attach hot shard) so the
    final window's served skew comes back under the 1.5 gate with the
    total replica budget conserved."""
    est = BlockSizeEstimator("tree").fit(store.load())
    # hot_size=2: the hot mass rides two keys, so the half-time jump
    # cleanly relocates it to a different shard (wider hot sets straddle
    # shards and dilute the shift); budget 12 over 4 shards gives the
    # apportionment enough granularity to track an ~80% hot shard
    trace = make_diurnal_trace(requests, _universe(("kmeans", "gmm")),
                               seed=seed, pattern="shifted_hotspot",
                               hot_size=2)
    half = len(trace) // 2
    budget = n_shards + 8
    plan = proportional_plan(
        trace_histogram(est, trace[:half], n_shards), budget)

    fleet = FleetRouter(est, n_shards=n_shards, replicas=plan,
                        transport="loopback", queue_depth=256,
                        admission="block", window_s=0.001)
    pol = AutoscalePolicy(budget=budget, moves_per_rebalance=budget,
                          rebalance_min_window=64, min_replicas=1,
                          max_replicas=budget)
    scaler = Autoscaler(fleet, pol)

    def settle(deadline_s=30.0):
        # migrations are transiently budget+1 while the donor drains
        t_end = time.time() + deadline_s
        while fleet.n_replicas > budget and time.time() < t_end:
            time.sleep(0.02)

    try:
        rep_first = run_load(fleet, trace[:half], n_clients=n_clients,
                             timeout=300)
        scaler.rebalance()        # provisioned-for window: a no-op move set
        settle()
        rest = trace[half:]
        detect, measure = rest[:len(rest) // 4], rest[len(rest) // 4:]
        # the hot set has just jumped; this window's histogram is the
        # evidence the rebalancer moves on
        rep_shift = run_load(fleet, detect, n_clients=n_clients,
                             timeout=300)
        scaler.rebalance()
        settle()
        rep_final = run_load(fleet, measure, n_clients=n_clients,
                             timeout=300)
        stats = fleet.stats()
    finally:
        fleet.close()

    reports = [rep_shift, rep_final]
    for r in (rep_first, *reports):
        assert r["errors"] == 0, f"serving errors: {r['first_error']}"
        assert r["served"] == r["requests"], (r["served"], r["requests"])
    assert stats["migrations"] >= 1, \
        f"rebalancer never moved a replica: {stats}"
    assert stats["n_replicas"] == budget, \
        f"budget not conserved: {stats['n_replicas']} != {budget}"
    assert rep_final["served_skew"] <= 1.5, \
        (f"served skew {rep_final['served_skew']:.2f} > 1.5 after "
         f"{stats['migrations']} migrations")
    assert rep_final["served_skew"] < rep_shift["served_skew"], \
        (f"migration did not reduce skew: {rep_shift['served_skew']:.2f} "
         f"-> {rep_final['served_skew']:.2f}")

    return {
        "requests": requests,
        "served": rep_first["served"] + sum(r["served"] for r in reports),
        "errors": sum(r["errors"] for r in (rep_first, *reports)),
        "budget": budget,
        "n_replicas_final": stats["n_replicas"],
        "migrations": stats["migrations"],
        "replica_plan": {str(s): n for s, n in sorted(plan.items())},
        "skew_provisioned": rep_first["served_skew"],
        "skew_after_shift": rep_shift["served_skew"],
        "skew_final": rep_final["served_skew"],
        "throughput_rps": rep_final["throughput_rps"],
        "wall_s": rep_first["wall_s"] + sum(r["wall_s"] for r in reports),
    }


# ------------------------------------------- 2c. fleet control plane
def _fleet_control(store, *, requests, n_clients, seed, workdir):
    """Registry-discovered socket fleet under the full control plane:
    heartbeat replacement of a silently-dead worker (no caller ever sees
    the crash), late-join adoption, authenticated-frame rejection, and a
    mid-trace checkpoint→restore onto a replacement router."""
    import socket as socketlib

    from repro.serve.transport import serve_socket_worker

    est = BlockSizeEstimator("tree").fit(store.load())
    trace = make_diurnal_trace(requests, _universe(("kmeans", "gmm")),
                               seed=seed, pattern="diurnal")
    third = len(trace) // 3

    # the swap target, so the checkpointed read barrier genuinely moves
    cursor = len(store)
    _sweep(store, "csvm", 224, 16, 34)
    new_records = [r for r, _src in store.follow(cursor)[0]]
    est_v2 = est.snapshot()
    assert est_v2.refit(new_records), "swap target did not retrain"

    key = "bench-fleet-secret"
    regpath = workdir / "fleet_registry.jsonl"
    reg = WorkerRegistry(regpath)
    servers = []

    def start_worker():
        srv = socketlib.create_server(("127.0.0.1", 0))
        addr = "%s:%d" % srv.getsockname()[:2]
        threading.Thread(target=serve_socket_worker, args=(srv,),
                         kwargs={"auth_key": key}, daemon=True).start()
        reg.announce(addr, ttl_s=600.0)
        servers.append(srv)
        return addr

    for _ in range(2):
        start_worker()

    spec = TransportSpec(kind="socket", registry=regpath, auth_key=key)
    fleet = FleetRouter(est, n_shards=2, transport=spec, queue_depth=256,
                        admission="block", window_s=0.001,
                        call_timeout_s=120.0,
                        heartbeat=HeartbeatPolicy(interval_s=0.1,
                                                  timeout_s=5.0,
                                                  miss_after=2))
    reports = []
    try:
        adopted = fleet.poll_registry()
        assert len(adopted) == 2, \
            f"registry discovery adopted {adopted}, wanted both workers"

        reports.append(run_load(fleet, trace[:third],
                                n_clients=n_clients, timeout=300))

        # ---- a worker dies silently; the prober replaces it before any
        # caller can eat a TransportDead
        fleet.silent_kill(0, replica=0)
        for _ in range(100):
            fleet.prober.probe_once()
            if fleet.stats()["heartbeat_replacements"] >= 1:
                break
            time.sleep(0.05)
        st_mid = fleet.stats()
        assert st_mid["heartbeat_replacements"] >= 1, \
            f"prober never replaced the silently-dead worker: {st_mid}"
        reports.append(run_load(fleet, trace[third:2 * third],
                                n_clients=n_clients, timeout=300))

        # ---- a new worker registers mid-flight; one poll adopts it
        start_worker()
        late = fleet.poll_registry()
        assert len(late) == 1, f"late joiner not adopted: {late}"

        # ---- roll the model, then checkpoint the management layer
        fleet.swap(est_v2)
        ckpt = workdir / "fleet_router.ckpt"
        fleet.checkpoint(ckpt)
        stats = fleet.stats()
    finally:
        fleet.close()

    # a replacement router must refuse a backend older than the
    # checkpointed read barrier (the staleness contract survives the
    # router, not just the process)
    try:
        FleetRouter.restore(ckpt, est, transport_kw={"auth_key": key})
        raise AssertionError("restore accepted a stale backend")
    except ValueError:
        stale_refused = True

    fleet2 = FleetRouter.restore(ckpt, est_v2,
                                 transport_kw={"auth_key": key})
    try:
        reports.append(run_load(fleet2, trace[2 * third:],
                                n_clients=n_clients, timeout=300))
        stats2 = fleet2.stats()
    finally:
        fleet2.close()

    # ---- forged / unauthenticated frames bounce with the typed error
    forged = {}
    target = start_worker()
    for label, bad in (("wrong_key", "not-" + key), ("no_key", "")):
        try:
            t = make_transport(
                TransportSpec(kind="socket", auth_key=bad), est,
                address=target)
            t.close()
        except FrameAuthError:
            forged[label] = "FrameAuthError"
    for srv in servers:
        srv.close()

    requests_total = sum(r["requests"] for r in reports)
    served = sum(r["served"] for r in reports)
    lost = sum(r["requests"] - r["served"] - r["rejected"] - r["expired"]
               for r in reports)
    errors = sum(r["errors"] for r in reports)
    stale = sum(r["staleness_violations"] for r in reports)
    wall = sum(r["wall_s"] for r in reports)
    rerouted = stats["rerouted"] + stats2["rerouted"]

    assert errors == 0, \
        f"errors through the control plane: {reports}"
    assert lost == 0, f"{lost} requests lost across replace + restore"
    assert stale == 0, f"{stale} staleness violations"
    assert rerouted == 0, \
        f"{rerouted} callers observed the silent crash (want heartbeat " \
        f"to win the race)"
    assert forged == {"wrong_key": "FrameAuthError",
                      "no_key": "FrameAuthError"}, \
        f"forged frames not rejected with the typed error: {forged}"
    assert stats2["read_barrier"] == est_v2.model_version, \
        f"restored read barrier regressed: {stats2['read_barrier']}"

    return {
        "requests": requests_total,
        "served": served,
        "lost": lost,
        "errors": errors,
        "staleness_violations": stale,
        "discovered": len(adopted),
        "late_adopted": len(late),
        "adoptions": stats["adoptions"],
        "crashes": stats["crashes"],
        "heartbeats": stats["heartbeats"],
        "heartbeat_replacements": stats["heartbeat_replacements"],
        "rerouted": rerouted,
        "forged_rejections": forged,
        "stale_restore_refused": stale_refused,
        "read_barrier": stats2["read_barrier"],
        "restored_served": reports[-1]["served"],
        "throughput_rps": served / wall,
        "wall_s": wall,
    }


# --------------------------------------------- 3. process-fleet speedup
def _fleet_speedup(store, *, requests, n_clients, n_shards, seed):
    """Single-process router vs multi-process fleet on the same
    memo-defeating trace (distinct env features per query -> every
    request is a model predict, the compute processes parallelize).
    Only meaningful on multi-core hosts; the 2x gate arms there."""
    est = BlockSizeEstimator("forest").fit(store.load())
    base = ENV.features()
    # 8192 distinct env variants + a small LRU: uniform traffic evicts
    # faster than it re-hits, so nearly every request runs the cascade
    universe = [(256 * (1 + i % 7), 16 * (1 + i % 5),
                 ("kmeans", "gmm")[i % 2], dict(base, ram_gb=16 + i))
                for i in range(8192)]
    trace = make_trace(requests, universe, seed=seed,
                       weights={"hot": 0.0, "zipf": 0.0, "uniform": 1.0,
                                "cold": 0.0})

    # batch_max 64 on both sides: identical batching, but it amortizes
    # the fleet's per-batch frame round-trip so the comparison measures
    # compute parallelism, not framing overhead
    with ShardRouter(est, n_shards=n_shards, queue_depth=512,
                     window_s=0.002, batch_max=64, maxsize=256) as router:
        single = run_load(router, trace, n_clients=n_clients, timeout=600)
    with FleetRouter(est, n_shards=n_shards, replicas=1,
                     transport="process", queue_depth=512,
                     window_s=0.002, batch_max=64, maxsize=256,
                     call_timeout_s=300.0) as fleet:
        multi = run_load(fleet, trace, n_clients=n_clients, timeout=600)

    assert single["errors"] == 0, single["first_error"]
    assert multi["errors"] == 0, multi["first_error"]
    assert multi["served"] == multi["requests"]
    speedup = multi["throughput_rps"] / max(single["throughput_rps"], 1e-9)
    cores = os.cpu_count() or 1
    if cores >= 4:
        assert speedup >= 2.0, \
            (f"process fleet only {speedup:.2f}x the single-process "
             f"router on {cores} cores (need >= 2x)")
    else:
        print(f"# note: {cores} core(s) — process-fleet speedup gate "
              f"needs >= 4 cores, measured {speedup:.2f}x", flush=True)
    return {
        "requests": requests,
        "single_rps": single["throughput_rps"],
        "fleet_rps": multi["throughput_rps"],
        "fleet_speedup": speedup,
        "single_hit_rate": single["router"]["hit_rate"],
        "cores": cores,
        "gated": cores >= 4,
    }


def run(verbose=True, *, rounds=2, requests_per_round=240, n_clients=4,
        n_shards=4, seed=0, diurnal_requests=100_000, diurnal_clients=16,
        full=False):
    t0 = time.time()
    with tempfile.TemporaryDirectory() as tmp:
        store = LogStore(Path(tmp) / "serve_store.jsonl")
        _sweep(store, "kmeans", 256, 16, seed=7)
        _sweep(store, "gmm", 192, 12, seed=8)

        results = _refit_scenario(store, rounds=rounds,
                                  requests_per_round=requests_per_round,
                                  n_clients=n_clients, n_shards=n_shards,
                                  seed=seed)
        csv_row("serving/load",
                1.0 / max(results["throughput_rps"], 1e-9) * 1e6,
                f"rps={results['throughput_rps']:.0f};"
                f"p99={results['p99_ms']:.2f}ms;"
                f"hit={results['hit_rate']:.2f};"
                f"skew={results['served_skew']:.2f};"
                f"stale={results['staleness_violations']};"
                f"swaps={results['refit_swaps']}")

        # the fleet sections reuse the store (the refit scenario's csvm
        # churn rounds already landed in it — fine: more evidence only
        # makes the models better, determinism comes from the traces)
        diurnal = _diurnal_fleet(
            store, requests=diurnal_requests, n_clients=diurnal_clients,
            n_shards=n_shards, seed=seed + 1,
            transport="process" if full else "loopback")
        results["fleet_diurnal"] = diurnal
        csv_row("serving/fleet_diurnal",
                1.0 / max(diurnal["throughput_rps"], 1e-9) * 1e6,
                f"transport={diurnal['transport']};"
                f"n={diurnal['requests']};"
                f"rps={diurnal['throughput_rps']:.0f};"
                f"skew={diurnal['served_skew']:.2f};"
                f"lost={diurnal['lost']};crashes={diurnal['crashes']};"
                f"stale={diurnal['staleness_violations']}")

        # socket fleet: same crash-racing-a-rolling-swap scenario, but
        # the frames cross real TCP connections and the "crash" is a
        # dropped connection (indistinguishable from a dead host)
        socket_requests = diurnal_requests if full else diurnal_requests // 5
        sock = _diurnal_fleet(
            store, requests=socket_requests,
            n_clients=diurnal_clients, n_shards=n_shards, seed=seed + 1,
            transport="socket", sweep_shape=(160, 24, 32))
        results["fleet_socket"] = sock
        csv_row("serving/fleet_socket",
                1.0 / max(sock["throughput_rps"], 1e-9) * 1e6,
                f"n={sock['requests']};"
                f"rps={sock['throughput_rps']:.0f};"
                f"skew={sock['served_skew']:.2f};"
                f"lost={sock['lost']};crashes={sock['crashes']};"
                f"stale={sock['staleness_violations']}")

        migration = _migration_fleet(
            store, requests=socket_requests, n_clients=diurnal_clients,
            n_shards=n_shards, seed=seed + 3)
        results["fleet_migration"] = migration
        csv_row("serving/fleet_migration",
                1.0 / max(migration["throughput_rps"], 1e-9) * 1e6,
                f"n={migration['requests']};"
                f"moves={migration['migrations']};"
                f"skew={migration['skew_after_shift']:.2f}"
                f"->{migration['skew_final']:.2f}")

        control = _fleet_control(
            store, requests=max(socket_requests // 4, 3000),
            n_clients=diurnal_clients, seed=seed + 4, workdir=Path(tmp))
        results["fleet_control"] = control
        csv_row("serving/fleet_control",
                1.0 / max(control["throughput_rps"], 1e-9) * 1e6,
                f"n={control['requests']};"
                f"discovered={control['discovered']};"
                f"hb_replace={control['heartbeat_replacements']};"
                f"rerouted={control['rerouted']};"
                f"lost={control['lost']};"
                f"stale={control['staleness_violations']}")

        if full:
            speedup = _fleet_speedup(store, requests=60_000,
                                     n_clients=16, n_shards=n_shards,
                                     seed=seed + 2)
            results["fleet_speedup"] = speedup
            csv_row("serving/fleet_speedup",
                    1.0 / max(speedup["fleet_rps"], 1e-9) * 1e6,
                    f"speedup={speedup['fleet_speedup']:.2f}x;"
                    f"single={speedup['single_rps']:.0f}rps;"
                    f"fleet={speedup['fleet_rps']:.0f}rps;"
                    f"cores={speedup['cores']}")

    results["wall_s"] = time.time() - t0
    csv_row("serving/refit_swap", results["wall_s"] * 1e6,
            f"shards={n_shards};invalidations={results['invalidations']};"
            f"cold={COLD_ALGO}:{results['cold_after_swap_chosen_by']}")
    OUT.write_text(json.dumps(results, indent=2) + "\n")
    if verbose:
        print(f"# wrote {OUT}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description="serving-tier load test")
    ap.add_argument("--smoke", action="store_true",
                    help="the fast CI configuration (this is the default)")
    ap.add_argument("--full", action="store_true",
                    help="nightly scale: multi-process fleet, 5x the "
                         "diurnal trace, the process-speedup section")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--diurnal-requests", type=int, default=None)
    args = ap.parse_args(argv)
    rounds = args.rounds or (4 if args.full else 2)
    requests = args.requests or (1000 if args.full else 240)
    clients = args.clients or (8 if args.full else 4)
    diurnal = args.diurnal_requests or (500_000 if args.full else 100_000)
    print("name,us_per_call,derived")
    return run(rounds=rounds, requests_per_round=requests,
               n_clients=clients, n_shards=args.shards, seed=args.seed,
               diurnal_requests=diurnal,
               diurnal_clients=32 if args.full else 16,
               full=args.full)


if __name__ == "__main__":
    main()
