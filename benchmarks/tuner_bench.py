"""Benchmarks for the shared tuning subsystem (core/tuner.py).

Writes ``BENCH_tuner.json`` at the repo root:

  * ``refit``   -- incremental ``Tuner.refit`` latency vs a full ``fit``:
                   the no-label-change fold (no retrain) and the
                   label-shifting fold (warm retrain from cached groups);
  * ``service`` -- ``TunerService`` warm hit-rate, the per-call overhead of
                   the model-version check, post-refit invalidation, and
                   the ``submit()``/``flush()`` micro-batching path;
  * ``parity``  -- cross-tuner label/prediction parity: each of the three
                   tuners vs an inline replication of its pre-refactor
                   module (direct ``log.training_set`` + cascade), asserted
                   equal on fixed seeds.

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.chained import ChainedClassifier
from repro.core.estimator import BlockSizeEstimator, EstimatorService
from repro.core.features import dataset_features, featurize, vectorize
from repro.core.log import ExecutionLog, ExecutionRecord
from repro.core.trees import DecisionTreeClassifier

from benchmarks.common import csv_row
from benchmarks.hotpath_bench import synthetic_log

OUT = Path(__file__).resolve().parent.parent / "BENCH_tuner.json"


def _best_of(fn, reps: int = 3):
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


# ----------------------------------------------------------------- refit
def bench_refit(results: dict):
    log = synthetic_log()
    t_fit, est = _best_of(lambda: BlockSizeEstimator("tree").fit(log))

    # same-label fold: a noisier re-measurement of every argmin cell --
    # argmin labels cannot move, so refit must skip retraining entirely
    same = [ExecutionRecord(r.dataset, r.algo, r.env, r.p_r, r.p_c,
                            r.time_s * 1.5)
            for r in log.best_per_group()]
    v0 = est.model_version
    t_noop, retrained = _best_of(lambda: est.refit(same))
    assert retrained is False and est.model_version == v0

    # label-shifting fold: one far-better measurement per kmeans group
    shifted = [ExecutionRecord(r.dataset, r.algo, r.env, 32, 4, 1e-9)
               for r in log.best_per_group() if r.algo == "kmeans"]
    t0 = time.perf_counter()
    retrained = est.refit(shifted)
    t_retrain = time.perf_counter() - t0
    assert retrained is True and est.model_version == v0 + 1

    results["refit"] = {
        "full_fit_s": t_fit, "refit_noop_s": t_noop,
        "refit_retrain_s": t_retrain,
        "noop_speedup_x": t_fit / max(t_noop, 1e-12),
    }
    csv_row("tuner/full_fit", t_fit * 1e6, "fit_from_log")
    csv_row("tuner/refit_noop", t_noop * 1e6,
            f"speedup={t_fit / max(t_noop, 1e-12):.0f}x;no_label_change")
    csv_row("tuner/refit_retrain", t_retrain * 1e6, "labels_shifted")


# --------------------------------------------------------------- service
def bench_service(results: dict, n_queries: int = 1024):
    est = BlockSizeEstimator("tree").fit(synthetic_log())
    rng = np.random.default_rng(2)
    qs = [(int(2 ** rng.integers(8, 16)), 64,
           ("kmeans", "pca", "rf", "csvm")[int(rng.integers(4))],
           {"n_workers": 4}) for _ in range(n_queries)]

    svc = EstimatorService(est)
    svc.predict_partitions_batch(qs)                       # warm the memo
    t_warm, warm = _best_of(lambda: svc.predict_partitions_batch(qs))
    t_raw, raw = _best_of(lambda: est.predict_partitions_batch(qs))
    assert warm == raw

    # micro-batching path: submit one by one, answer in one flush
    def flush_path():
        handles = [svc.submit(q) for q in qs]
        out = svc.flush()
        assert handles[0].done
        return out
    t_flush, flushed = _best_of(flush_path)
    assert flushed == warm

    # post-refit invalidation: memo flushed exactly once, answers move
    inv0 = svc.invalidations
    shifted = [ExecutionRecord(r.dataset, r.algo, r.env, 32, 4, 1e-9)
               for r in synthetic_log().best_per_group()]
    est.refit(shifted)
    fresh = svc.predict_partitions_batch(qs)
    assert svc.invalidations == inv0 + 1
    assert fresh != warm, "refit on shifted labels must change predictions"
    assert fresh == est.predict_partitions_batch(qs)

    results["service"] = {
        "queries": n_queries,
        "raw_batch_s": t_raw, "service_warm_s": t_warm,
        "flush_s": t_flush,
        "hit_rate": svc.hit_rate,
        "warm_speedup_x": t_raw / t_warm,
        "invalidations": svc.invalidations,
    }
    csv_row("tuner/service_warm", t_warm / n_queries * 1e6,
            f"hit_rate={svc.hit_rate:.2f};speedup={t_raw / t_warm:.1f}x")
    csv_row("tuner/service_flush", t_flush / n_queries * 1e6,
            "submit+flush_micro_batching")
    csv_row("tuner/service_invalidation", 0.0,
            f"invalidations={svc.invalidations};stale_memos=0")


# ---------------------------------------------------------------- parity
def _old_cascade_fit(log: ExecutionLog, max_depth: int = 10):
    """The pre-refactor path every tuner hand-rolled: training_set ->
    vectorize -> chained cascade."""
    feats, yr, yc = log.training_set()
    X, order = vectorize(feats)
    model = ChainedClassifier(
        lambda: DecisionTreeClassifier(max_depth=max_depth)).fit(X, yr, yc)
    return model, order


def bench_parity(results: dict):
    parity = {}

    # ds-array estimator
    log = synthetic_log()
    model, order = _old_cascade_fit(log)
    rng = np.random.default_rng(3)
    qs = [(int(2 ** rng.integers(8, 16)), 64,
           ("kmeans", "pca", "rf", "csvm")[int(rng.integers(4))],
           {"n_workers": 4}) for _ in range(256)]
    feats = [featurize(dataset_features(nr, nc), a, e) for nr, nc, a, e in qs]
    E = model.predict(vectorize(feats, order)[0])
    old = [(min(int(2 ** max(int(er), 0)), nr),
            min(int(2 ** max(int(ec), 0)), nc))
           for (nr, nc, _, _), (er, ec) in zip(qs, E)]
    new = BlockSizeEstimator("tree").fit(log).predict_partitions_batch(qs)
    parity["estimator"] = old == new
    assert old == new, "estimator diverged from pre-refactor module"

    # kernel tile tuner
    from repro.core.kerneltune import (KernelTuner, build_training_log,
                                       shape_features)
    klog = build_training_log(n_shapes=10)
    model, order = _old_cascade_fit(klog)
    shapes = [(int(2 ** rng.integers(7, 13)), int(2 ** rng.integers(7, 12)),
               int(2 ** rng.integers(7, 13))) for _ in range(64)]
    feats = [featurize(shape_features(m, k, n), "matmul_tile",
                       {"vmem_mb": 16}) for m, k, n in shapes]
    E = model.predict(vectorize(feats, order)[0])
    old = [(min(int(2 ** int(er)), m), min(int(2 ** int(ec)), n))
           for (m, k, n), (er, ec) in zip(shapes, E)]
    new = KernelTuner().fit(klog).predict_batch(shapes)
    # predict now returns full (bm, bn, bk): the (bm, bn) prefix keeps the
    # pre-refactor parity contract, bk comes from the third chained stage
    parity["kernel"] = old == [t[:2] for t in new]
    assert parity["kernel"], "kernel tuner diverged from pre-refactor module"
    assert all(len(t) == 3 and t[2] >= 1 for t in new), \
        "kernel tuner must predict a full (bm, bn, bk) tile"

    # mesh tuner (raw cascade exponents; the feasibility snap downstream
    # of the protocol is shared by both paths)
    from repro.configs import SHAPES, get_config
    from repro.core.meshtune import MeshTuner, arch_features, tune_all
    mlog, _ = tune_all(["yi-6b", "mamba2-370m"], shapes=("train_4k",),
                       chips=64)
    model, order = _old_cascade_fit(mlog, max_depth=12)
    tun = MeshTuner(64).fit(mlog)
    f = featurize(arch_features(get_config("deepseek-7b"),
                                SHAPES["train_4k"]), "meshtune", {"chips": 64})
    old_e = model.predict(vectorize([f], order)[0])
    new_e = tun.tuner.model.predict(
        vectorize([f], tun.tuner.feature_order)[0])
    parity["mesh"] = bool(np.array_equal(old_e, new_e))
    assert parity["mesh"], "mesh tuner cascade diverged"

    results["parity"] = parity
    csv_row("tuner/parity", 0.0,
            ";".join(f"{k}={'ok' if v else 'DIVERGED'}"
                     for k, v in parity.items()))


def run(verbose=True):
    results: dict = {}
    bench_refit(results)
    bench_service(results)
    bench_parity(results)
    OUT.write_text(json.dumps(results, indent=2) + "\n")
    if verbose:
        print(f"# wrote {OUT}")
    return results


if __name__ == "__main__":
    run()
