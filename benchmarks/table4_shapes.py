"""Paper Table IV / Figs. 4-5: row-imbalanced, column-imbalanced and
balanced synthetic datasets, K-means and RF, full hybrid grids."""
from __future__ import annotations

import numpy as np

from repro.core.estimator import BlockSizeEstimator
from repro.data.datasets import shape_cases

from benchmarks.common import ENV64, build_training_log, csv_row, eval_on


def run(scale: float = 0.008, verbose: bool = True):
    log = build_training_log(verbose=verbose)
    est = BlockSizeEstimator("tree").fit(log)
    cases = shape_cases(scale)
    rows = []
    for case, (X, y) in cases.items():
        for algo in ("kmeans", "rf"):
            r = eval_on(est, X, y, algo, ENV64, mult=1)
            r.update({"algo": algo, "case": case,
                      "rows": X.shape[0], "cols": X.shape[1]})
            rows.append(r)
            csv_row(f"table4/{algo}_{case}", r["t_star"] * 1e6,
                    f"ratio_avg={r['ratio_avg']:.2f};"
                    f"ratio_worst={r['ratio_worst']:.2f};"
                    f"pred=({r['p_r']};{r['p_c']});best={r['best_part']}")
    by_algo = {}
    for algo in ("kmeans", "rf"):
        sel = [r for r in rows if r["algo"] == algo]
        by_algo[algo] = {k: float(np.mean([r[k] for r in sel]))
                         for k in ("ratio_best", "ratio_avg", "ratio_worst",
                                   "red_best", "red_avg", "red_worst")}
    return rows, by_algo


if __name__ == "__main__":
    run()
