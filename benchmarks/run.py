"""Benchmark harness -- one module per paper table/figure plus the
beyond-paper tuners and the roofline report.

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import argparse
import time

SUITES = ("table2", "table3", "table4", "table6", "ablation", "meshtune",
          "kernel", "roofline", "hotpath", "taskgraph", "tuner", "eval",
          "serving", "fault")
# fast suites with built-in correctness asserts -- CI runs these on every
# push so bench modules can't silently rot between full runs
SMOKE_SUITES = ("hotpath", "taskgraph", "tuner", "eval", "serving", "fault",
                "kernel")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    choices=SUITES, help="subset of suites")
    ap.add_argument("--smoke", action="store_true",
                    help=f"run only the fast smoke suites {SMOKE_SUITES}")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    todo = args.only or (SMOKE_SUITES if args.smoke else SUITES)
    verbose = not args.quiet

    print("name,us_per_call,derived")
    t0 = time.time()
    if "table2" in todo:
        from benchmarks import table2_realworld
        table2_realworld.run(verbose=verbose)
    if "table3" in todo:
        from benchmarks import table3_synthetic
        table3_synthetic.run(verbose=verbose)
    if "table4" in todo:
        from benchmarks import table4_shapes
        table4_shapes.run(verbose=verbose)
    if "table6" in todo:
        from benchmarks import table6_multinode
        table6_multinode.run(verbose=verbose)
    if "ablation" in todo:
        from benchmarks import ablation_models
        ablation_models.run(verbose=verbose)
    if "meshtune" in todo:
        from benchmarks import meshtune_bench
        meshtune_bench.run(verbose=verbose)
    if "kernel" in todo:
        from benchmarks import kernel_bench
        kernel_bench.run(verbose=verbose)
    if "roofline" in todo:
        from benchmarks import roofline
        roofline.run(verbose=verbose)
    if "hotpath" in todo:
        from benchmarks import hotpath_bench
        hotpath_bench.run(verbose=verbose)
    if "taskgraph" in todo:
        from benchmarks import taskgraph_bench
        taskgraph_bench.run(verbose=verbose)
    if "tuner" in todo:
        from benchmarks import tuner_bench
        tuner_bench.run(verbose=verbose)
    if "eval" in todo:
        from benchmarks import eval_bench
        eval_bench.run(verbose=verbose)
    if "serving" in todo:
        from benchmarks import serving_bench
        serving_bench.run(verbose=verbose)
    if "fault" in todo:
        from benchmarks import fault_bench
        fault_bench.run(verbose=verbose)
    print(f"# benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
