"""Benchmark + correctness asserts for the closed-loop evaluation
subsystem (src/repro/eval/).

Writes ``BENCH_eval.json`` at the repo root:

  * harness metrics — exact-hit rate, exponent distance, and modeled
    speedup vs the default ds-array blocking over the smoke dataset grid
    (all five algorithms, three environment profiles);
  * ``closed_loop`` — the predict → execute → log → refit → invalidate
    audit trail, asserted on every run: the first run of an unseen
    algorithm falls back to the default heuristic, its record refits the
    model, the serving memo is flushed, and the second run is answered by
    the model.

Prints ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import json
import math
import tempfile
import time
from pathlib import Path

from repro.data.logstore import LogStore
from repro.eval.autorun import closed_loop_demo
from repro.eval.harness import ALGOS, bench_payload, evaluate

from benchmarks.common import csv_row

OUT = Path(__file__).resolve().parent.parent / "BENCH_eval.json"


def run(verbose=True):
    t0 = time.time()
    report = evaluate(smoke=True, verbose=False)
    t_harness = time.time() - t0

    overall = report["overall"]
    # the harness must produce a labeled, predicted group for every one of
    # the paper's five workloads in every environment profile
    for algo in ALGOS:
        m = report["per_algo"][algo]
        assert m["groups"] > 0, f"no evaluation groups for {algo}"
        assert "mean_speedup_vs_default" in m, \
            f"no feasible speedup measurement for {algo}"
    assert 0.0 <= overall["exact_hit_rate"] <= 1.0
    assert math.isfinite(overall["mean_exp_distance"])
    # in-sample predictions come from the argmin labels themselves: the
    # predicted cell must not run slower than the default blocking overall
    assert overall["mean_speedup_vs_default"] >= 1.0, \
        f"predicted partitionings slower than default: {overall}"

    with tempfile.TemporaryDirectory() as tmp:
        t1 = time.time()
        store = LogStore(Path(tmp) / "loop_store.jsonl")
        trail = closed_loop_demo(store)
        t_loop = time.time() - t1
    assert trail["first_chosen_by"] == "default", trail
    assert trail["second_chosen_by"] == "model", trail
    assert trail["first_retrained"] is True, trail
    assert trail["versions"][1] > trail["versions"][0], trail
    assert trail["invalidations"] >= 1, trail
    assert trail["appended"][0] is True, trail
    assert trail["store_sources"].get("autorun", 0) >= 1, trail
    report["closed_loop"] = trail

    results = bench_payload(report)
    results["harness_wall_s"] = t_harness
    results["closed_loop_wall_s"] = t_loop
    OUT.write_text(json.dumps(results, indent=2) + "\n")

    csv_row("eval/harness", t_harness * 1e6,
            f"hit={overall['exact_hit_rate']:.2f};"
            f"expdist={overall['mean_exp_distance']:.2f};"
            f"speedup_vs_default={overall['mean_speedup_vs_default']:.2f}x")
    csv_row("eval/closed_loop", t_loop * 1e6,
            f"first={trail['first_chosen_by']};"
            f"second={trail['second_chosen_by']};"
            f"invalidations={trail['invalidations']}")
    if verbose:
        print(f"# wrote {OUT}")
    return results


if __name__ == "__main__":
    run()
